"""AOT compile path: lower every model x batch-bucket to HLO text.

Run once by `make artifacts`; python never runs at serving time.  Emits:

    artifacts/<model>_b<batch>.hlo.txt   HLO *text* (NOT .serialize() -- the
                                         image's xla_extension 0.5.1 rejects
                                         jax>=0.5 64-bit-id protos; the text
                                         parser reassigns ids, see
                                         /opt/xla-example/README.md)
    artifacts/golden/<model>.{dense,indices,output}.bin
                                         raw little-endian tensors for the
                                         rust-side numeric round-trip test
    artifacts/manifest.json              parameter ABI (seed/shape/scale per
                                         tensor), input layouts, buckets,
                                         golden shapes -- everything the rust
                                         runtime needs to regenerate weights
                                         and drive the executables.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import params as pinit

DEFAULT_BUCKETS = (1, 16, 64, 256)
GOLDEN_BATCH = 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: M.ModelConfig, batch: int) -> str:
    """Lower one model at one batch bucket to HLO text."""
    specs = M.param_specs(cfg)
    param_structs = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in specs]
    dense_s = jax.ShapeDtypeStruct((batch, M.DENSE_DIM), jnp.float32)
    idx_s = jax.ShapeDtypeStruct((batch, cfg.total_lookups), jnp.int32)

    def fn(plist, dense, idx):
        return M.forward(cfg, plist, dense, idx)

    # keep_unused=True: NCF/DIN/DIEN/WnD have no bottom MLP so `dense` would
    # otherwise be DCE'd out of the entry signature, breaking the uniform
    # (params..., dense, indices) ABI the rust runtime relies on.
    lowered = jax.jit(fn, keep_unused=True).lower(param_structs, dense_s, idx_s)
    return to_hlo_text(lowered)


def write_golden(cfg: M.ModelConfig, out_dir: str) -> dict:
    """Run the model in python and dump input/output binaries for rust."""
    golden_dir = os.path.join(out_dir, "golden")
    os.makedirs(golden_dir, exist_ok=True)
    dense, idx = M.example_inputs(cfg, GOLDEN_BATCH)
    out = M.run(cfg, GOLDEN_BATCH)
    paths = {}
    for tag, arr in (("dense", dense), ("indices", idx), ("output", out)):
        rel = os.path.join("golden", f"{cfg.name}.{tag}.bin")
        arr.tofile(os.path.join(out_dir, rel))
        paths[tag] = rel
    return {
        "batch": GOLDEN_BATCH,
        "files": paths,
        "output_shape": list(out.shape),
    }


def build_manifest(buckets: tuple[int, ...]) -> dict:
    manifest: dict = {
        "version": 1,
        "rows_per_table": M.ROWS_PER_TABLE,
        "dense_dim": M.DENSE_DIM,
        "buckets": list(buckets),
        "models": {},
    }
    for name, cfg in M.MODELS.items():
        manifest["models"][name] = {
            "domain": cfg.domain,
            "sla_ms": cfg.sla_ms,
            "table_gb": cfg.table_gb,
            "fc_mb": cfg.fc_mb,
            "n_tables": cfg.n_tables,
            "dim": cfg.dim,
            "lookups": cfg.lookups,
            "pooling": cfg.pooling,
            "seq_len": cfg.seq_len,
            "total_lookups": cfg.total_lookups,
            "bottom_mlp": list(cfg.bottom_mlp),
            "top_mlp": list(cfg.top_mlp),
            "params": [
                {"name": s.name, "shape": list(s.shape), "seed": s.seed,
                 "scale": s.scale}
                for s in M.param_specs(cfg)
            ],
            "artifacts": {str(b): f"{name}_b{b}.hlo.txt" for b in buckets},
        }
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--models", default="all",
                    help="comma-separated model names (default: all)")
    ap.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)))
    args = ap.parse_args()

    buckets = tuple(int(b) for b in args.buckets.split(","))
    names = list(M.MODELS) if args.models == "all" else args.models.split(",")
    os.makedirs(args.out, exist_ok=True)

    manifest = build_manifest(buckets)
    total = 0
    for name in names:
        cfg = M.MODELS[name]
        for b in buckets:
            t0 = time.time()
            text = lower_model(cfg, b)
            path = os.path.join(args.out, f"{name}_b{b}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            total += len(text)
            print(f"  {name:8s} b={b:<4d} {len(text)/1e3:8.1f} KB "
                  f"({time.time() - t0:.1f}s)")
        manifest["models"][name]["golden"] = write_golden(cfg, args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(names)} models x {len(buckets)} buckets "
          f"({total / 1e6:.1f} MB HLO text) -> {args.out}")


if __name__ == "__main__":
    main()
