"""Deterministic, language-portable parameter initialization.

The rust runtime (rust/src/runtime/params.rs) regenerates every model
parameter from the (seed, shape, scale) triples recorded in
artifacts/manifest.json, using the *same* SplitMix64-based counter scheme
implemented here.  This keeps multi-megabyte weight blobs out of the
artifact directory entirely: python and rust independently materialize
bit-identical f32 tensors, so the golden input/output pair produced by
aot.py verifies the whole AOT chain numerically.

Scheme (must match rust/src/runtime/params.rs exactly):

    h      = splitmix64(seed * GOLDEN + element_index)      (u64, wrapping)
    mant   = h >> 40                                        (top 24 bits)
    value  = (mant / 2^24) * 2*scale - scale                (f32 in [-scale, scale))
"""

from __future__ import annotations

import numpy as np

_M64 = np.uint64(0xFFFFFFFFFFFFFFFF)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized SplitMix64 finalizer over a uint64 array."""
    with np.errstate(over="ignore"):
        z = (x + _GOLDEN) & _M64
        z = ((z ^ (z >> np.uint64(30))) * _MIX1) & _M64
        z = ((z ^ (z >> np.uint64(27))) * _MIX2) & _M64
        return z ^ (z >> np.uint64(31))


def fill_uniform(seed: int, shape: tuple[int, ...], scale: float) -> np.ndarray:
    """Deterministic f32 tensor with values uniform in [-scale, scale)."""
    n = int(np.prod(shape)) if shape else 1
    idx = np.arange(n, dtype=np.uint64)
    with np.errstate(over="ignore"):
        base = (np.uint64(seed) * _GOLDEN) & _M64
        h = splitmix64((base + idx) & _M64)
    mant = (h >> np.uint64(40)).astype(np.float64)  # 24 bits
    vals = (mant / float(1 << 24)) * (2.0 * scale) - scale
    return vals.astype(np.float32).reshape(shape)


def fill_indices(seed: int, shape: tuple[int, ...], rows: int) -> np.ndarray:
    """Deterministic int32 index tensor with values uniform in [0, rows)."""
    n = int(np.prod(shape)) if shape else 1
    idx = np.arange(n, dtype=np.uint64)
    with np.errstate(over="ignore"):
        base = (np.uint64(seed) * _GOLDEN) & _M64
        h = splitmix64((base + idx) & _M64)
    vals = (h % np.uint64(rows)).astype(np.int32)
    return vals.reshape(shape)
