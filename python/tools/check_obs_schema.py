#!/usr/bin/env python3
"""Validate `hera-obs-v1` observability artifacts.

Usage:
    check_obs_schema.py DIR [--require-decisions] [--require-hps]
                            [--metrics-text FILE]

DIR must hold obs_registry.json and obs_events.jsonl (as written by
`hera obs-dump --out DIR`).  --metrics-text additionally parses a saved
Prometheus text exposition (e.g. a `curl /metrics` capture from
`hera obs-serve`) and cross-checks the per-tenant stage histograms and
RMU counters CI's smoke test expects.  --require-hps checks that the
hierarchical-parameter-server families (per-(model, tier) read counters,
per-tier latency histograms, queue-depth and prefetch-overlap gauges)
made it into the registry snapshot.
"""

import argparse
import json
import sys
from pathlib import Path

SCHEMA = "hera-obs-v1"
METRIC_TYPES = ("counter", "gauge", "histogram")
EVENT_KINDS = ("alloc_change", "alloc_outcome", "hps_decision")
STAGES = ("queue", "compute", "cache", "total")
HPS_FAMILIES = {
    "hera_hps_reads_total": ("model", "tier"),
    "hera_hps_tier_latency_seconds": ("model", "tier"),
    "hera_hps_queue_depth": ("tier",),
    "hera_hps_prefetch_overlap": ("model",),
}


def check_registry(path):
    doc = json.loads(path.read_text())
    assert doc["schema"] == SCHEMA, f"registry schema {doc.get('schema')!r}"
    metrics = doc["metrics"]
    assert isinstance(metrics, list) and metrics, "registry snapshot is empty"
    names = set()
    for m in metrics:
        assert isinstance(m["name"], str) and m["name"], m
        assert m["type"] in METRIC_TYPES, m
        assert isinstance(m["labels"], dict), m
        if m["type"] == "histogram":
            buckets = m["buckets"]
            assert isinstance(buckets, list) and buckets, m
            total = sum(b["count"] for b in buckets)
            assert total == m["count"], (
                f"{m['name']}: bucket sum {total} != count {m['count']}"
            )
            bounds = [b["le"] for b in buckets if b["le"] != "+Inf"]
            assert bounds == sorted(bounds), f"{m['name']}: bounds not ascending"
            assert m["p95"] >= 0, m
        else:
            assert isinstance(m["value"], (int, float)), m
        names.add(m["name"])
    return doc, names


def check_journal(path, require_decisions):
    lines = [l for l in path.read_text().splitlines() if l.strip()]
    if require_decisions:
        assert lines, "journal is empty but decisions were required"
    kinds = {k: 0 for k in EVENT_KINDS}
    for i, line in enumerate(lines):
        e = json.loads(line)
        assert e["schema"] == SCHEMA, f"line {i + 1}: schema {e.get('schema')!r}"
        assert e["seq"] == i, f"line {i + 1}: seq {e['seq']} breaks the 0..n order"
        assert isinstance(e["t_s"], (int, float)), e
        kind = e["event"]
        assert kind in EVENT_KINDS, f"line {i + 1}: unknown event {kind!r}"
        kinds[kind] += 1
        if kind == "alloc_change":
            for key in ("tenant", "model", "from", "to", "window_p95_s",
                        "window_arrival_qps", "slack", "predicted_qps"):
                assert key in e, f"alloc_change line {i + 1} missing {key!r}"
            for side in ("from", "to"):
                assert set(e[side]) == {"workers", "ways", "cache_bytes"}, e[side]
        elif kind == "hps_decision":
            # Prefetch-overlap knob steps: from/to are scalar fractions,
            # not allocation objects.
            for key in ("tenant", "model", "knob", "from", "to", "slack",
                        "window_p95_s", "window_arrival_qps"):
                assert key in e, f"hps_decision line {i + 1} missing {key!r}"
            assert e["knob"] == "prefetch", e
            for side in ("from", "to"):
                v = e[side]
                assert isinstance(v, (int, float)) and 0.0 <= v <= 1.0, e
            assert e["from"] != e["to"], f"hps_decision line {i + 1} is a no-op"
        else:
            for key in ("tenant", "model", "decided_t_s", "predicted_qps",
                        "realized_qps", "delta_qps"):
                assert key in e, f"alloc_outcome line {i + 1} missing {key!r}"
            delta = e["realized_qps"] - e["predicted_qps"]
            assert abs(e["delta_qps"] - delta) < 1e-9, e
    if require_decisions:
        assert kinds["alloc_change"] > 0, "no alloc_change events recorded"
        assert kinds["alloc_outcome"] > 0, "no alloc_outcome events recorded"
    return kinds


def check_hps_registry(doc):
    """Every HPS family present, correctly typed and labelled, non-empty."""
    expected_type = {
        "hera_hps_reads_total": "counter",
        "hera_hps_tier_latency_seconds": "histogram",
        "hera_hps_queue_depth": "gauge",
        "hera_hps_prefetch_overlap": "gauge",
    }
    by_name = {}
    for m in doc["metrics"]:
        by_name.setdefault(m["name"], []).append(m)
    for family, label_keys in HPS_FAMILIES.items():
        series = by_name.get(family)
        assert series, f"HPS family {family!r} missing from the registry"
        for m in series:
            assert m["type"] == expected_type[family], m
            assert set(m["labels"]) == set(label_keys), (
                f"{family}: labels {sorted(m['labels'])} != {sorted(label_keys)}"
            )
    tiers = {m["labels"]["tier"] for m in by_name["hera_hps_reads_total"]}
    assert tiers, "no tier ever served a read"
    reads = sum(m["value"] for m in by_name["hera_hps_reads_total"])
    assert reads > 0, "hera_hps_reads_total is all zero"
    for m in by_name["hera_hps_tier_latency_seconds"]:
        assert m["count"] > 0, f"empty tier latency histogram: {m['labels']}"
    return tiers


def parse_prometheus(text):
    """Parse Prometheus text exposition into {(name, labels_str): value}."""
    samples = {}
    for ln, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.rsplit(" ", 1)
        assert len(parts) == 2, f"metrics line {ln}: {line!r}"
        key, value = parts
        samples[key] = float(value)  # raises on malformed values
    return samples


def check_metrics_text(path, require_decisions):
    samples = parse_prometheus(path.read_text())
    assert samples, "metrics text holds no samples"
    stage_counts = [
        k for k in samples
        if k.startswith("hera_query_stage_latency_seconds_count{")
    ]
    assert stage_counts, "no per-tenant stage histograms exported"
    for stage in STAGES:
        matching = [k for k in stage_counts if f'stage="{stage}"' in k]
        assert matching, f"stage {stage!r} missing from the exposition"
    assert any(k.startswith("hera_emu_percent") for k in samples), "EMU gauge missing"
    assert any(k.startswith("hera_rmu_windows_total") for k in samples)
    if require_decisions:
        decided = sum(
            v for k, v in samples.items()
            if k.startswith("hera_rmu_decisions_total{")
        )
        assert decided > 0, "RMU decision counters are all zero"
        p95s = [
            v for k, v in samples.items()
            if k.startswith("hera_query_stage_latency_seconds_p95{")
            and 'stage="total"' in k
        ]
        assert p95s and all(v > 0 for v in p95s), "per-tenant total p95 gauges empty"
    return len(samples)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("dir", type=Path)
    ap.add_argument("--require-decisions", action="store_true")
    ap.add_argument("--require-hps", action="store_true")
    ap.add_argument("--metrics-text", type=Path, default=None)
    args = ap.parse_args()

    doc, names = check_registry(args.dir / "obs_registry.json")
    assert "hera_query_stage_latency_seconds" in names, names
    kinds = check_journal(args.dir / "obs_events.jsonl", args.require_decisions)
    print(f"obs_registry.json: ok ({len(names)} metric families)")
    print(
        "obs_events.jsonl: ok "
        f"({kinds['alloc_change']} changes, {kinds['alloc_outcome']} outcomes, "
        f"{kinds['hps_decision']} hps decisions)"
    )
    if args.require_hps:
        tiers = check_hps_registry(doc)
        print(f"hps families: ok (tiers: {', '.join(sorted(tiers))})")
    if args.metrics_text is not None:
        n = check_metrics_text(args.metrics_text, args.require_decisions)
        print(f"{args.metrics_text}: ok ({n} samples)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
