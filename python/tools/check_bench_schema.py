#!/usr/bin/env python3
"""Validate `hera-bench-v1` perf-trajectory documents.

Usage:
    check_bench_schema.py DIR [--universe N] [--provenance P] [--min-models M]

DIR must hold BENCH_affinity.json and BENCH_schedule.json (as written by
`hera bench-snapshot --out DIR`).  CI runs this twice: once against a
freshly generated smoke snapshot (--universe/--provenance pinned) and
once against the baselines checked into the repo root (--min-models 200,
the trajectory's required scale point).
"""

import argparse
import json
import sys
from pathlib import Path

RESIDENCIES = ("optimistic", "strict", "cached")


def check_rows(doc, name):
    rows = doc["results"]
    assert isinstance(rows, list) and rows, f"{name}: empty results"
    for r in rows:
        assert isinstance(r["name"], str) and r["name"], r
        assert r["iters"] >= 1, r
        assert r["mean_ns"] > 0, r
        assert r["p99_ns"] >= r["p50_ns"] > 0, r
        assert 0 < r["min_ns"] <= r["mean_ns"] + 1e-9, r


def check_plans(doc, min_models):
    plans = doc["plans"]
    assert isinstance(plans, list) and len(plans) >= 3, (
        "schedule doc needs seed + universe optimistic/cached plan rows"
    )
    for p in plans:
        assert isinstance(p["name"], str) and p["name"], p
        assert p["models"] >= 2, p
        assert p["max_group"] >= 2, p
        assert p["residency"] in RESIDENCIES, p
        assert p["servers"] > 0, p
        assert p["serviced_qps"] > 0, p
        assert p["target_qps"] > 0, p
        assert p["meets_targets"] is True, p
        assert p["memo_entries"] >= 0, p
    if min_models is not None:
        biggest = max(p["models"] for p in plans)
        assert biggest >= min_models, (
            f"largest plan covers {biggest} models, need >= {min_models}"
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("dir", type=Path)
    ap.add_argument("--universe", type=int, default=None)
    ap.add_argument("--provenance", default=None)
    ap.add_argument("--min-models", type=int, default=None)
    args = ap.parse_args()

    for name, group in (
        ("BENCH_affinity.json", "affinity"),
        ("BENCH_schedule.json", "schedule"),
    ):
        doc = json.loads((args.dir / name).read_text())
        assert doc["schema"] == "hera-bench-v1", f"{name}: schema {doc.get('schema')!r}"
        assert doc["group"] == group, f"{name}: group {doc.get('group')!r}"
        assert isinstance(doc["provenance"], str) and doc["provenance"], name
        if args.provenance is not None:
            assert doc["provenance"] == args.provenance, doc["provenance"]
        assert doc["universe_models"] >= 2, name
        if args.universe is not None:
            assert doc["universe_models"] == args.universe, doc["universe_models"]
        assert doc["seed"] >= 0, name
        assert doc["threads"] >= 1, name
        check_rows(doc, name)
        if group == "schedule":
            assert doc["max_group"] >= 2, name
            check_plans(doc, args.min_models)
        print(f"{name}: ok ({len(doc['results'])} results)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
