#!/usr/bin/env python3
"""Validate `hera-bench-v1` perf-trajectory documents.

Usage:
    check_bench_schema.py DIR [--universe N] [--provenance P]
                              [--min-models M] [--require-solver]

DIR must hold BENCH_affinity.json, BENCH_schedule.json and
BENCH_solver.json (as written by `hera bench-snapshot --out DIR`).  CI
runs this three ways: against a freshly generated smoke snapshot
(--universe/--provenance pinned), against the fast-solver perf smoke
with --require-solver (the counter-based acceptance: memo hits, beam
counters and probes-per-search ratios, which are deterministic where
wall-clock speedups are not), and against the baselines at the repo
root (--min-models 200).

`estimated-bootstrap` provenance is tolerated only where no Rust
toolchain exists (the authoring container has none): when `cargo` is on
PATH the measured numbers are one command away, so an estimated
document is a hard FAIL, not a warning.
"""

import argparse
import json
import shutil
import sys
from pathlib import Path

RESIDENCIES = ("optimistic", "strict", "cached", "mixed")

# Per-mode search-cost counter deltas reported by the solver document
# (must mirror `benchsnap::SOLVER_COUNTERS`).
SOLVER_COUNTERS = (
    "hera_solver_searches_total",
    "hera_solver_probes_total",
    "hera_solver_fast_path_total",
    "hera_hitcurve_memo_hits_total",
    "hera_hitcurve_memo_misses_total",
    "hera_erlang_table_hits_total",
    "hera_erlang_table_misses_total",
    "hera_hitcurve_table_hits_total",
    "hera_hitcurve_table_misses_total",
    "hera_group_memo_hits_total",
    "hera_group_memo_misses_total",
    "hera_beam_candidates_total",
    "hera_beam_pruned_total",
)

# The legacy coupled-solver search: 12 rounds of fixed-grid bisection,
# one probe per round.  The slow A/B pass must reproduce it exactly.
BISECTION_PROBES_PER_SEARCH = 12.0


def check_provenance(doc, name, pinned):
    prov = doc["provenance"]
    assert isinstance(prov, str) and prov, name
    if pinned is not None:
        assert prov == pinned, f"{name}: provenance {prov!r}, pinned {pinned!r}"
    if prov.startswith("estimated"):
        msg = (
            f"{name}: provenance is {prov!r} but a rust toolchain is "
            "available — regenerate with `cargo run --release -- "
            "bench-snapshot` instead of shipping estimates"
        )
        assert shutil.which("cargo") is None, msg
        print(f"{name}: WARNING estimated provenance (no toolchain here)")


def check_rows(doc, name):
    rows = doc["results"]
    assert isinstance(rows, list) and rows, f"{name}: empty results"
    for r in rows:
        assert isinstance(r["name"], str) and r["name"], r
        assert r["iters"] >= 1, r
        assert r["mean_ns"] > 0, r
        assert r["p99_ns"] >= r["p50_ns"] > 0, r
        assert 0 < r["min_ns"] <= r["mean_ns"] + 1e-9, r


def check_plans(doc, min_models):
    plans = doc["plans"]
    assert isinstance(plans, list) and len(plans) >= 3, (
        "schedule doc needs seed + universe optimistic/cached plan rows"
    )
    for p in plans:
        assert isinstance(p["name"], str) and p["name"], p
        assert p["models"] >= 2, p
        assert p["max_group"] >= 2, p
        assert p["residency"] in RESIDENCIES, p
        assert p["servers"] > 0, p
        assert p["serviced_qps"] > 0, p
        assert p["target_qps"] > 0, p
        assert p["meets_targets"] is True, p
        assert p["memo_entries"] >= 0, p
    if min_models is not None:
        biggest = max(p["models"] for p in plans)
        assert biggest >= min_models, (
            f"largest plan covers {biggest} models, need >= {min_models}"
        )


def check_solver(doc, require_solver):
    name = "BENCH_solver.json"
    assert doc["plans_identical"] is True, (
        f"{name}: the fast solver changed a plan — the A/B passes must "
        "be bit-identical"
    )
    assert doc["fast_solver"] in ("on", "off", "auto"), doc["fast_solver"]
    assert doc["beam_score"] in ("affinity", "demand"), doc["beam_score"]

    phase = doc["schedule_phase"]
    assert phase["slow_total_ns"] > 0, phase
    assert phase["fast_total_ns"] > 0, phase
    assert phase["speedup"] > 0, phase
    for policy in ("optimistic", "cached"):
        row = phase[policy]
        assert row["slow_ns"] > 0 and row["fast_ns"] > 0, row
        assert row["speedup"] > 0, row

    counters = doc["counters"]
    for mode in ("slow", "fast"):
        c = counters[mode]
        for key in SOLVER_COUNTERS:
            assert isinstance(c[key], (int, float)) and c[key] >= 0, (
                f"{name}: counters.{mode}.{key} missing or negative"
            )
        assert c["hera_solver_searches_total"] > 0, (
            f"{name}: {mode} pass ran no scale searches"
        )
    slow, fast = counters["slow"], counters["fast"]
    slow_ratio = (
        slow["hera_solver_probes_total"] / slow["hera_solver_searches_total"]
    )
    fast_ratio = (
        fast["hera_solver_probes_total"] / fast["hera_solver_searches_total"]
    )
    assert slow_ratio == BISECTION_PROBES_PER_SEARCH, (
        f"{name}: slow pass spent {slow_ratio} probes/search, the legacy "
        f"bisection spends exactly {BISECTION_PROBES_PER_SEARCH}"
    )
    assert fast_ratio < slow_ratio, (
        f"{name}: fast pass spent {fast_ratio} probes/search — no better "
        "than bisection"
    )
    assert slow["hera_solver_fast_path_total"] == 0, (
        f"{name}: the slow pass took the fast path"
    )
    assert fast["hera_solver_fast_path_total"] > 0, (
        f"{name}: the fast pass never took the fast path"
    )

    if not require_solver:
        return
    # Counter-based perf acceptance (deterministic under CI noise).
    memo = fast["hera_hitcurve_memo_hits_total"]
    memo_total = memo + fast["hera_hitcurve_memo_misses_total"]
    assert memo_total > 0 and memo > 0, (
        f"{name}: fast pass recorded no hit-rate memo hits "
        f"({memo}/{memo_total})"
    )
    print(
        f"{name}: hitcurve memo hit-rate "
        f"{memo / memo_total:.3f} ({memo:.0f}/{memo_total:.0f})"
    )
    assert fast["hera_group_memo_hits_total"] > 0, (
        f"{name}: fast pass recorded no group-memo hits"
    )
    for mode in ("slow", "fast"):
        assert counters[mode]["hera_beam_candidates_total"] > 0, (
            f"{name}: {mode} pass generated no beam candidates"
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("dir", type=Path)
    ap.add_argument("--universe", type=int, default=None)
    ap.add_argument("--provenance", default=None)
    ap.add_argument("--min-models", type=int, default=None)
    ap.add_argument(
        "--require-solver",
        action="store_true",
        help="enforce the fast-solver counter acceptance (memo hit-rate "
        "> 0, group-memo hits, beam counters) on BENCH_solver.json",
    )
    args = ap.parse_args()

    for name, group in (
        ("BENCH_affinity.json", "affinity"),
        ("BENCH_schedule.json", "schedule"),
        ("BENCH_solver.json", "solver"),
    ):
        doc = json.loads((args.dir / name).read_text())
        assert doc["schema"] == "hera-bench-v1", f"{name}: schema {doc.get('schema')!r}"
        assert doc["group"] == group, f"{name}: group {doc.get('group')!r}"
        check_provenance(doc, name, args.provenance)
        assert doc["universe_models"] >= 2, name
        if args.universe is not None:
            assert doc["universe_models"] == args.universe, doc["universe_models"]
        assert doc["seed"] >= 0, name
        assert doc["threads"] >= 1, name
        check_rows(doc, name)
        if group == "schedule":
            assert doc["max_group"] >= 2, name
            check_plans(doc, args.min_models)
        if group == "solver":
            assert doc["max_group"] >= 2, name
            check_solver(doc, args.require_solver)
        print(f"{name}: ok ({len(doc['results'])} results)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
