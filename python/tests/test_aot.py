"""AOT path checks: HLO text validity, manifest completeness, goldens."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_hlo_text_structure(self):
        text = aot.lower_model(M.MODELS["ncf"], batch=2)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # params (12) + dense + indices = 14 entry parameters (0..13); nested
        # computations re-number from 0, so check the max ordinal instead.
        assert "parameter(13)" in text
        assert "parameter(14)" not in text

    def test_hlo_has_single_tuple_root(self):
        text = aot.lower_model(M.MODELS["din"], batch=1)
        # return_tuple=True wraps the single output; rust uses to_tuple1().
        assert "ROOT" in text and "tuple(" in text

    def test_batch_appears_in_shapes(self):
        text = aot.lower_model(M.MODELS["ncf"], batch=5)
        assert "f32[5,13]" in text        # dense input
        assert "s32[5,4]" in text         # indices input
        assert "f32[5,1]" in text         # output

    def test_manifest_covers_all_models(self):
        man = aot.build_manifest((1, 16))
        assert set(man["models"]) == set(M.MODELS)
        for name, entry in man["models"].items():
            cfg = M.MODELS[name]
            assert entry["total_lookups"] == cfg.total_lookups
            assert len(entry["params"]) == len(M.param_specs(cfg))
            assert set(entry["artifacts"]) == {"1", "16"}


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
class TestArtifactsOnDisk:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_exists(self, manifest):
        for entry in manifest["models"].values():
            for rel in entry["artifacts"].values():
                assert os.path.exists(os.path.join(ART, rel)), rel

    def test_goldens_roundtrip(self, manifest):
        """Re-running the model in python must reproduce the stored golden."""
        for name, entry in manifest["models"].items():
            g = entry["golden"]
            out_path = os.path.join(ART, g["files"]["output"])
            stored = np.fromfile(out_path, np.float32).reshape(g["output_shape"])
            fresh = M.run(M.MODELS[name], g["batch"])
            np.testing.assert_allclose(fresh, stored, rtol=1e-5, atol=1e-6)

    def test_golden_inputs_match_example_inputs(self, manifest):
        for name, entry in manifest["models"].items():
            cfg = M.MODELS[name]
            g = entry["golden"]
            dense, idx = M.example_inputs(cfg, g["batch"])
            d2 = np.fromfile(os.path.join(ART, g["files"]["dense"]),
                             np.float32).reshape(dense.shape)
            i2 = np.fromfile(os.path.join(ART, g["files"]["indices"]),
                             np.int32).reshape(idx.shape)
            np.testing.assert_array_equal(dense, d2)
            np.testing.assert_array_equal(idx, i2)
