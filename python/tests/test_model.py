"""L2 model-zoo checks: shapes, determinism, probability range, config sanity."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import params as pinit


ALL = sorted(M.MODELS)


class TestConfigs:
    def test_eight_models(self):
        assert len(M.MODELS) == 8

    @pytest.mark.parametrize("name", ALL)
    def test_lookup_layout_consistent(self, name):
        cfg = M.MODELS[name]
        assert len(cfg.lookups_per_table) == cfg.n_tables
        assert sum(cfg.lookups_per_table) == cfg.total_lookups
        assert all(l > 0 for l in cfg.lookups_per_table)

    def test_table1_values(self):
        """Spot-check the zoo against the paper's Table I."""
        assert M.MODELS["dlrm_b"].n_tables == 40
        assert M.MODELS["dlrm_b"].lookups == 120
        assert M.MODELS["dlrm_b"].table_gb == 25.0
        assert M.MODELS["dlrm_b"].sla_ms == 400.0
        assert M.MODELS["dlrm_d"].dim == 256
        assert M.MODELS["ncf"].sla_ms == 5.0
        assert M.MODELS["dien"].n_tables == 43
        assert M.MODELS["wnd"].top_mlp[:3] == (1024, 512, 256)

    @pytest.mark.parametrize("name", ALL)
    def test_param_specs_unique_names_and_seeds(self, name):
        specs = M.param_specs(M.MODELS[name])
        names = [s.name for s in specs]
        seeds = [s.seed for s in specs]
        assert len(set(names)) == len(names)
        assert len(set(seeds)) == len(seeds)

    def test_seeds_unique_across_models(self):
        seeds = []
        for name in ALL:
            seeds += [s.seed for s in M.param_specs(M.MODELS[name])]
        assert len(set(seeds)) == len(seeds)


class TestForward:
    @pytest.mark.parametrize("name", ALL)
    def test_output_shape_and_range(self, name):
        out = M.run(M.MODELS[name], 4)
        assert out.shape == (4, 1)
        assert np.isfinite(out).all()
        assert (out > 0).all() and (out < 1).all()  # sigmoid output

    @pytest.mark.parametrize("name", ALL)
    def test_deterministic(self, name):
        a = M.run(M.MODELS[name], 3)
        b = M.run(M.MODELS[name], 3)
        np.testing.assert_array_equal(a, b)

    def test_batch_consistency(self):
        """Row i of a batch must equal the same sample run at batch=1."""
        cfg = M.MODELS["dlrm_a"]
        plist = [jnp.asarray(p) for p in M.materialize_params(cfg)]
        dense, idx = M.example_inputs(cfg, 4)
        full = np.asarray(M.forward(cfg, plist, jnp.asarray(dense), jnp.asarray(idx)))
        for i in range(4):
            one = np.asarray(M.forward(
                cfg, plist,
                jnp.asarray(dense[i:i + 1]), jnp.asarray(idx[i:i + 1])))
            np.testing.assert_allclose(one, full[i:i + 1], rtol=1e-4, atol=1e-5)

    def test_take_tril(self):
        z = jnp.asarray(np.arange(2 * 3 * 3, dtype=np.float32).reshape(2, 3, 3))
        out = np.asarray(M.take_tril(z))
        # strict lower triangle of a 3x3: elements (1,0),(2,0),(2,1)
        assert out.shape == (2, 3)
        np.testing.assert_array_equal(out[0], [3.0, 6.0, 7.0])

    @settings(max_examples=8, deadline=None)
    @given(batch=st.integers(1, 32), name=st.sampled_from(["ncf", "din", "wnd"]))
    def test_hypothesis_batches(self, batch, name):
        out = M.run(M.MODELS[name], batch)
        assert out.shape == (batch, 1)
        assert np.isfinite(out).all()


class TestParamsPortability:
    """The deterministic init is the ABI with rust — pin exact values."""

    def test_splitmix_known_values(self):
        # Pinned so the rust implementation can assert the same constants.
        h = pinit.splitmix64(np.asarray([0], np.uint64))[0]
        assert int(h) == 0xE220A8397B1DCDAF
        h = pinit.splitmix64(np.asarray([1], np.uint64))[0]
        assert int(h) == 0x910A2DEC89025CC1

    def test_fill_uniform_range_and_determinism(self):
        a = pinit.fill_uniform(42, (1000,), 0.5)
        b = pinit.fill_uniform(42, (1000,), 0.5)
        np.testing.assert_array_equal(a, b)
        assert (a >= -0.5).all() and (a < 0.5).all()
        assert abs(float(a.mean())) < 0.05  # roughly centered

    def test_fill_uniform_pinned_head(self):
        v = pinit.fill_uniform(7, (4,), 1.0)
        # Values pinned for cross-language verification (the rust
        # runtime::params tests assert these same four floats).
        expected = np.asarray(
            [0.5430930852890015, 0.046134352684020996,
             0.4781745672225952, 0.7774368524551392], np.float32)
        np.testing.assert_array_equal(v, expected)
        assert v.dtype == np.float32

    def test_fill_indices_range(self):
        ix = pinit.fill_indices(3, (64, 8), 100)
        assert ix.dtype == np.int32
        assert (ix >= 0).all() and (ix < 100).all()

    def test_different_seeds_differ(self):
        a = pinit.fill_uniform(1, (100,), 1.0)
        b = pinit.fill_uniform(2, (100,), 1.0)
        assert not np.array_equal(a, b)
