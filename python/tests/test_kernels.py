"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; every test asserts allclose against
kernels.ref.  This is the core build-time correctness signal for the HLO
artifacts (the same kernel instances are lowered into them).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import sls, dot_interaction, ref
from compile import params as pinit

F32 = jnp.float32
BF16 = jnp.bfloat16


def _table(rows, dim, dtype, seed=1):
    return jnp.asarray(pinit.fill_uniform(seed, (rows, dim), 1.0), dtype)


def _indices(batch, lookups, rows, seed=2):
    return jnp.asarray(pinit.fill_indices(seed, (batch, lookups), rows))


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == BF16 else dict(rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- SLS ----

class TestSls:
    def test_basic_sum(self):
        t, ix = _table(64, 16, F32), _indices(4, 5, 64)
        np.testing.assert_allclose(sls(t, ix), ref.sls_ref(t, ix), rtol=1e-5)

    def test_basic_mean(self):
        t, ix = _table(64, 16, F32), _indices(4, 5, 64)
        np.testing.assert_allclose(
            sls(t, ix, mode="mean"), ref.sls_ref(t, ix, mode="mean"), rtol=1e-5)

    def test_single_lookup_is_gather(self):
        t, ix = _table(32, 8, F32), _indices(6, 1, 32)
        out = np.asarray(sls(t, ix))
        exp = np.asarray(t)[np.asarray(ix)[:, 0]]
        np.testing.assert_allclose(out, exp, rtol=1e-6)

    def test_batch_one(self):
        t, ix = _table(128, 32, F32), _indices(1, 9, 128)
        np.testing.assert_allclose(sls(t, ix), ref.sls_ref(t, ix), rtol=1e-5)

    def test_repeated_indices(self):
        t = _table(16, 4, F32)
        ix = jnp.asarray([[3, 3, 3, 3]], jnp.int32)
        exp = 4.0 * np.asarray(t)[3]
        np.testing.assert_allclose(np.asarray(sls(t, ix))[0], exp, rtol=1e-5)

    def test_zero_table_gives_zero(self):
        t = jnp.zeros((8, 8), F32)
        ix = _indices(3, 4, 8)
        assert float(np.abs(np.asarray(sls(t, ix))).max()) == 0.0

    def test_first_and_last_row(self):
        t = _table(50, 8, F32)
        ix = jnp.asarray([[0, 49]], jnp.int32)
        exp = np.asarray(t)[0] + np.asarray(t)[49]
        np.testing.assert_allclose(np.asarray(sls(t, ix))[0], exp, rtol=1e-5)

    def test_bf16(self):
        t, ix = _table(64, 16, BF16), _indices(4, 5, 64)
        out = np.asarray(sls(t, ix), np.float32)
        exp = np.asarray(ref.sls_ref(t, ix), np.float32)
        np.testing.assert_allclose(out, exp, **_tol(BF16))

    def test_bad_mode_raises(self):
        t, ix = _table(8, 4, F32), _indices(1, 1, 8)
        with pytest.raises(ValueError):
            sls(t, ix, mode="max")

    def test_dtype_preserved(self):
        t, ix = _table(8, 4, BF16), _indices(2, 3, 8)
        assert sls(t, ix).dtype == BF16

    @settings(max_examples=40, deadline=None)
    @given(
        batch=st.integers(1, 33),
        lookups=st.integers(1, 40),
        rows=st.integers(2, 300),
        dim=st.sampled_from([4, 8, 16, 32, 64, 128, 256]),
        dtype=st.sampled_from([F32, BF16]),
        mode=st.sampled_from(["sum", "mean"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, batch, lookups, rows, dim, dtype,
                                    mode, seed):
        t = _table(rows, dim, dtype, seed=seed)
        ix = _indices(batch, lookups, rows, seed=seed + 1)
        out = np.asarray(sls(t, ix, mode=mode), np.float32)
        exp = np.asarray(ref.sls_ref(t, ix, mode=mode), np.float32)
        # Pooling error grows with lookup count for bf16.
        tol = _tol(dtype)
        if dtype == BF16:
            tol = dict(rtol=2e-2, atol=2e-2 * max(1, lookups // 4))
        np.testing.assert_allclose(out, exp, **tol)


# -------------------------------------------------------- interaction ----

class TestDotInteraction:
    def test_basic(self):
        x = jnp.asarray(pinit.fill_uniform(3, (4, 9, 16), 1.0))
        np.testing.assert_allclose(
            dot_interaction(x), ref.dot_interaction_ref(x), rtol=1e-4, atol=1e-4)

    def test_symmetry(self):
        x = jnp.asarray(pinit.fill_uniform(4, (2, 5, 8), 1.0))
        z = np.asarray(dot_interaction(x))
        np.testing.assert_allclose(z, np.swapaxes(z, 1, 2), rtol=1e-5)

    def test_diagonal_is_squared_norm(self):
        x = jnp.asarray(pinit.fill_uniform(5, (3, 4, 8), 1.0))
        z = np.asarray(dot_interaction(x))
        xs = np.asarray(x)
        for b in range(3):
            np.testing.assert_allclose(
                np.diag(z[b]), (xs[b] ** 2).sum(-1), rtol=1e-5)

    def test_identity_vectors(self):
        x = jnp.broadcast_to(jnp.eye(4, dtype=F32), (2, 4, 4))
        z = np.asarray(dot_interaction(x))
        np.testing.assert_allclose(z[0], np.eye(4), atol=1e-6)

    def test_single_vector(self):
        x = jnp.asarray(pinit.fill_uniform(6, (2, 1, 16), 1.0))
        z = np.asarray(dot_interaction(x))
        assert z.shape == (2, 1, 1)

    @settings(max_examples=30, deadline=None)
    @given(
        batch=st.integers(1, 17),
        t=st.integers(1, 44),
        dim=st.sampled_from([4, 8, 16, 32, 64, 128, 256]),
        dtype=st.sampled_from([F32, BF16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, batch, t, dim, dtype, seed):
        x = jnp.asarray(pinit.fill_uniform(seed, (batch, t, dim), 1.0), dtype)
        out = np.asarray(dot_interaction(x), np.float32)
        exp = np.asarray(ref.dot_interaction_ref(x), np.float32)
        tol = dict(rtol=3e-2, atol=3e-2) if dtype == BF16 else dict(rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(out, exp, **tol)


# ------------------------------------------------------ attention ref ----

class TestAttentionRef:
    def test_weights_sum_to_one_effect(self):
        # Uniform history rows -> attention returns that row regardless of query.
        row = pinit.fill_uniform(9, (8,), 1.0)
        hist = jnp.asarray(np.broadcast_to(row, (2, 5, 8)).copy())
        q = jnp.asarray(pinit.fill_uniform(10, (2, 8), 1.0))
        out = np.asarray(ref.attention_pool_ref(hist, q))
        np.testing.assert_allclose(out, np.broadcast_to(row, (2, 8)), rtol=1e-5)

    def test_sharp_attention_picks_aligned_row(self):
        hist = np.zeros((1, 3, 4), np.float32)
        hist[0, 0] = [100, 0, 0, 0]
        hist[0, 1] = [0, 1, 0, 0]
        hist[0, 2] = [0, 0, 1, 0]
        q = np.asarray([[1.0, 0, 0, 0]], np.float32)
        out = np.asarray(ref.attention_pool_ref(jnp.asarray(hist), jnp.asarray(q)))
        np.testing.assert_allclose(out[0], hist[0, 0], rtol=1e-4, atol=1e-6)
