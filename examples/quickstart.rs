//! Quickstart: load one AOT-compiled recommendation model and run a few
//! inferences through the PJRT runtime — the smallest possible tour of
//! the L1/L2 (Pallas/JAX, build time) -> L3 (rust, serving time) stack.
//!
//! Run `make artifacts` first, then:
//!     cargo run --release --example quickstart

use hera::runtime::{manifest::default_artifact_dir, Engine};

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    println!("loading NCF from {} ...", dir.display());
    let engine = Engine::load(&dir, Some(&["ncf"]), Some(&[1, 16, 64]))?;

    // Verify the end-to-end numerics against the python-recorded golden.
    let err = engine.verify_golden("ncf")?;
    println!("golden verified (max abs err {err:.2e})");

    // Rank a batch of 16 candidate items for one user.
    let (dense, indices) = engine.example_inputs("ncf", 16);
    let out = engine.infer("ncf", 16, &dense, &indices)?;
    println!("bucket used: {}  exec time: {:.3} ms", out.bucket, out.exec_s * 1e3);
    let mut ranked: Vec<(usize, f32)> =
        out.probs.iter().copied().enumerate().collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top-5 recommended items (index, CTR):");
    for (idx, p) in ranked.iter().take(5) {
        println!("  item {idx:2}  p(click) = {p:.4}");
    }

    // Odd batch sizes pad into the nearest bucket transparently.
    let (dense5, idx5) = engine.example_inputs("ncf", 5);
    let out5 = engine.infer("ncf", 5, &dense5, &idx5)?;
    println!(
        "batch 5 -> bucket {} ({} probabilities returned)",
        out5.bucket,
        out5.probs.len()
    );
    Ok(())
}
