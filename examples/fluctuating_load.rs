//! Fluctuating-load scenario (paper Fig. 14): DLRM(D) + NCF co-located
//! while query arrival rates ramp, drop at T1 and spike at T2; compares
//! how Hera's RMU and PARTIES track the changes.
//!
//!     cargo run --release --example fluctuating_load

use hera::baselines::PartiesController;
use hera::config::{ModelId, NodeConfig};
use hera::hera::HeraRmu;
use hera::profiler::ProfileStore;
use hera::server_sim::{Controller, SimulatedTenant, Simulation};

fn main() -> anyhow::Result<()> {
    let store = ProfileStore::build(&NodeConfig::paper_default());
    let d = ModelId::from_name("dlrm_d").unwrap();
    let n = ModelId::from_name("ncf").unwrap();
    let dur = 60.0;

    for use_parties in [false, true] {
        let name = if use_parties { "PARTIES" } else { "Hera RMU" };
        let tenants = [
            SimulatedTenant {
                model: d,
                workers: 8,
                ways: 5,
                arrival_qps: store.profile(d).max_load(),
                cache_bytes: None,
            },
            SimulatedTenant {
                model: n,
                workers: 8,
                ways: 6,
                arrival_qps: store.profile(n).max_load(),
                cache_bytes: None,
            },
        ];
        let mut sim = Simulation::new(NodeConfig::paper_default(), &tenants, 99);
        sim.set_monitor_interval(0.5);
        sim.set_load_trace(vec![
            (0.0, vec![0.3, 0.3]),
            (9.0, vec![0.5, 0.4]),
            (17.0, vec![0.7, 0.5]),
            (24.0, vec![0.7, 0.2]),  // T1: NCF load drops
            (42.0, vec![0.1, 0.6]),  // T2: NCF spikes, DLRM(D) collapses
        ]);
        let mut hera_rmu;
        let mut parties;
        let controller: &mut dyn Controller = if use_parties {
            parties = PartiesController::new(NodeConfig::paper_default());
            &mut parties
        } else {
            hera_rmu = HeraRmu::new(&store);
            &mut hera_rmu
        };
        sim.run(dur, 0.0, controller);

        let mut violations = 0;
        let mut windows = 0;
        let mut worst: f64 = 0.0;
        for &(_, _, norm) in &sim.latency_timeline {
            windows += 1;
            if norm > 1.0 {
                violations += 1;
            }
            worst = worst.max(norm);
        }
        println!("=== {name} ===");
        println!(
            "  SLA-violating monitor windows: {violations}/{windows} ({:.1}%), worst p95 = {:.2}x SLA",
            100.0 * violations as f64 / windows as f64,
            worst
        );
        println!("  allocation changes: {}", sim.alloc_timeline.len());
        // Show the allocation trajectory around the T2 spike.
        let around_t2: Vec<_> = sim
            .alloc_timeline
            .iter()
            .filter(|(t, _, _)| (40.0..50.0).contains(t))
            .collect();
        for (t, tenant, rv) in around_t2.iter().take(8) {
            let m = if *tenant == 0 { "dlrm_d" } else { "ncf" };
            println!(
                "    t={t:5.1}s  {m:7} -> {} workers / {} ways",
                rv.workers, rv.ways
            );
        }
    }
    Ok(())
}
