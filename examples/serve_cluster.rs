//! END-TO-END DRIVER: the full Hera stack on a real workload.
//!
//! 1. Profiles the model zoo and picks a Hera co-location pair
//!    (Algorithms 1-2) for one node.
//! 2. Loads the real AOT artifacts (Pallas SLS + interaction kernels
//!    inside JAX-lowered HLO) into the PJRT engine.
//! 3. Serves Poisson traffic with heavy-tail batch sizes through the
//!    multi-tenant coordinator, with worker allocations taken from the
//!    Hera plan, and reports latency/throughput against the SLAs.
//!
//! Results of this run are recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example serve_cluster

use std::sync::Arc;
use std::time::Duration;

use hera::alloc::ResidencyPolicy;
use hera::config::NodeConfig;
use hera::coordinator::{run_load, Coordinator, LoadGenSpec, TenantConfig};
use hera::hera::AffinityMatrix;
use hera::profiler::ProfileStore;
use hera::runtime::{manifest::default_artifact_dir, Engine};

fn main() -> anyhow::Result<()> {
    // ---- Phase 1: offline Hera planning on the node model ----
    println!("[1/3] profiling + affinity (Algorithms 1-2)...");
    let store = ProfileStore::build(&NodeConfig::paper_default());
    let matrix = AffinityMatrix::build(&store);
    let (low, high) = store.partition_by_scalability();
    let a = low[1]; // dlrm_d — the bandwidth-limited model
    let b = matrix.best_partner(a, &high).unwrap();
    let plan = hera::hera::cluster::evaluate_group(
        &store,
        &matrix,
        &[a, b],
        ResidencyPolicy::Optimistic,
    );
    anyhow::ensure!(plan.tenants.len() == 2, "expected a pair plan");
    let workers = (plan.tenants[0].rv.workers, plan.tenants[1].rv.workers);
    println!("  co-locating {plan}");

    // ---- Phase 2: load the real models ----
    println!("[2/3] loading PJRT engine (AOT artifacts)...");
    let dir = default_artifact_dir();
    let engine = Arc::new(Engine::load(&dir, Some(&[a.name(), b.name()]), None)?);
    for m in [a.name(), b.name()] {
        let err = engine.verify_golden(m)?;
        println!("  golden {m}: max abs err {err:.2e}");
    }

    // ---- Phase 3: serve real traffic ----
    // Worker counts follow the Hera plan, scaled to this host's cores.
    let host_cores = std::thread::available_parallelism()?.get().max(2);
    let scale = (host_cores as f64 / 16.0).min(1.0);
    let w_a = ((workers.0 as f64 * scale) as usize).max(1);
    let w_b = ((workers.1 as f64 * scale) as usize).max(1);
    println!("[3/3] serving on {host_cores} host cores: {} x{}, {} x{}", a.name(), w_a, b.name(), w_b);

    // Table-I SLAs assume the paper's 16-core Xeon; scale them to this
    // host's core budget so the report is meaningful on small machines.
    let sla = |m: hera::config::ModelId| Some(m.spec().sla_ms / scale);
    let coord = Coordinator::start(
        engine,
        &[
            TenantConfig { model: a.name().into(), workers: w_a, sla_ms: sla(a) },
            TenantConfig { model: b.name().into(), workers: w_b, sla_ms: sla(b) },
        ],
    )?;
    // Offered load: modest rates that a small CI host can sustain; the
    // figure-grade throughput numbers come from the calibrated simulator.
    // Scale offered load to the host too (the paper's rates assume 16
    // dedicated cores; CI hosts may have 2).
    let specs = vec![
        LoadGenSpec {
            model: a.name().into(),
            arrival_qps: (2.0 * scale * w_a as f64).max(0.5),
            max_batch: 128,
        },
        LoadGenSpec {
            model: b.name().into(),
            arrival_qps: (12.0 * scale * w_b as f64).max(2.0),
            max_batch: 128,
        },
    ];
    let reports = run_load(&coord, &specs, Duration::from_secs(10), 42)?;

    println!("\n{:8} {:>8} {:>9} {:>9} {:>9} {:>9} {:>7}", "model", "queries", "qps", "p50(ms)", "p95(ms)", "p99(ms)", "viol%");
    for r in &reports {
        println!(
            "{:8} {:>8} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>6.2}%",
            r.model, r.completed, r.achieved_qps, r.p50_ms, r.p95_ms, r.p99_ms,
            100.0 * r.violation_rate
        );
    }
    coord.shutdown();
    println!("\nend-to-end OK: Pallas kernels -> JAX HLO -> PJRT -> rust coordinator");
    Ok(())
}
