//! Co-location study: the full offline Hera pipeline on the simulated
//! node — profile the model zoo, classify worker scalability, build the
//! Algorithm-1 affinity matrix, and schedule a cluster (Algorithm 2),
//! comparing against the DeepRecSys / Random baselines.
//!
//!     cargo run --release --example colocation_study

use hera::baselines::SelectionPolicy;
use hera::config::{ModelId, NodeConfig, N_MODELS};
use hera::figures::emu_pair_analytic;
use hera::hera::{AffinityMatrix, ClusterScheduler};
use hera::profiler::ProfileStore;

fn main() -> anyhow::Result<()> {
    println!("profiling the 8-model zoo on the Table-II node...");
    let store = ProfileStore::build(&NodeConfig::paper_default());
    let (low, high) = store.partition_by_scalability();
    println!(
        "worker scalability: low = {:?}, high = {:?}",
        low.iter().map(|m| m.name()).collect::<Vec<_>>(),
        high.iter().map(|m| m.name()).collect::<Vec<_>>()
    );

    println!("\nco-location affinity (Algorithm 1), low-scalability rows:");
    let matrix = AffinityMatrix::build(&store);
    print!("{:10}", "");
    for b in ModelId::all() {
        print!("{:>8}", &b.name()[..b.name().len().min(7)]);
    }
    println!();
    for &a in &low {
        print!("{:10}", a.name());
        for b in ModelId::all() {
            if a == b {
                print!("{:>8}", "-");
            } else {
                print!("{:>8.3}", matrix.get(a, b).system);
            }
        }
        println!();
    }

    println!("\nbest partners + pair EMU:");
    for &a in &low {
        let b = matrix.best_partner(a, &high).unwrap();
        let emu = emu_pair_analytic(&store, a, b);
        println!(
            "  {} -> {}  (affinity {:.3}, EMU {:.0}%)",
            a.name(),
            b.name(),
            matrix.get(a, b).system,
            emu
        );
    }

    println!("\ncluster scheduling (Algorithm 2) @ 1000 QPS per model:");
    let targets = [1000.0; N_MODELS];
    let hera_plan = ClusterScheduler::new(&store, &matrix).schedule(&targets)?;
    for policy in [SelectionPolicy::DeepRecSys, SelectionPolicy::Random] {
        let plan = policy.schedule(&store, &matrix, &targets, 42)?;
        println!("  {:12} {:3} servers", policy.name(), plan.num_servers());
    }
    println!("  {:12} {:3} servers", "Hera", hera_plan.num_servers());
    Ok(())
}
